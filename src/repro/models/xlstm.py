"""xLSTM blocks: mLSTM (matrix memory, chunk-parallel) and sLSTM (scalar
memory, sequential recurrence) [arXiv:2405.04517].

TPU adaptation: the mLSTM parallel dual is evaluated chunk-wise exactly like
the Mamba2 SSD path (MXU matmuls within chunks, a short lax.scan across
chunks carrying the [H, Dk, Dv] matrix memory and [H, Dk] normalizer).  The
sLSTM recurrence is inherently sequential (recurrent weights R on h_{t-1});
it runs as a lax.scan over time — length-independent HLO, the TPU-idiomatic
form of what CUDA implementations fuse into a persistent kernel.

Gates follow the stabilized formulation: sigmoid forget gate, exponential
input gate with max-stabilizer m (sLSTM); the mLSTM chunked path uses
sigmoid f / sigmoid-scaled i (a standard stabilized reimplementation choice;
noted in DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import rmsnorm, scaled_init


def _dims(cfg):
    d = cfg.d_model
    d_in = int(cfg.ssm.proj_factor * d)
    h = cfg.num_heads
    p = d_in // h
    return d, d_in, h, p


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg):
    d, d_in, h, p = _dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "up": scaled_init(ks[0], (d, 2 * d_in), d),        # x, z(gate)
        "wq": scaled_init(ks[1], (d_in, d_in), d_in),
        "wk": scaled_init(ks[2], (d_in, d_in), d_in),
        "wv": scaled_init(ks[3], (d_in, d_in), d_in),
        "wi": scaled_init(ks[4], (d_in, h), d_in),
        "wf": scaled_init(ks[5], (d_in, h), d_in),
        "fb": jnp.full((h,), 3.0, jnp.float32),            # forget-gate bias
        "norm": jnp.ones((d_in,), jnp.float32),
        "down": scaled_init(ks[6], (d_in, d), d_in),
    }


def _mlstm_qkvif(cfg, params, xs):
    d, d_in, h, p = _dims(cfg)
    b, s, _ = xs.shape
    q = (xs @ params["wq"].astype(xs.dtype)).reshape(b, s, h, p)
    k = (xs @ params["wk"].astype(xs.dtype)).reshape(b, s, h, p) / jnp.sqrt(float(p))
    v = (xs @ params["wv"].astype(xs.dtype)).reshape(b, s, h, p)
    i = jax.nn.sigmoid((xs @ params["wi"].astype(xs.dtype)).astype(jnp.float32))
    f = jax.nn.sigmoid(
        (xs @ params["wf"].astype(xs.dtype)).astype(jnp.float32) + params["fb"])
    return q, k, v, i, f


def mlstm_forward(cfg, params, x, state=None):
    """x [B,S,D] -> (y [B,S,D], (C [B,H,Dk,Dv], n [B,H,Dk]))."""
    d, d_in, h, p = _dims(cfg)
    b, s, _ = x.shape
    up = x @ params["up"].astype(x.dtype)
    xs, z = jnp.split(up, 2, axis=-1)
    q, k, v, i, f = _mlstm_qkvif(cfg, params, xs)

    qf = min(cfg.ssm.chunk_size, s)
    nc = max(1, s // qf)
    assert nc * qf == s, f"seq {s} not divisible by chunk {qf}"
    qc = q.reshape(b, nc, qf, h, p).astype(jnp.float32)
    kc = k.reshape(b, nc, qf, h, p).astype(jnp.float32)
    vc = v.reshape(b, nc, qf, h, p).astype(jnp.float32)
    ic = i.reshape(b, nc, qf, h)
    log_f = jnp.log(f + 1e-9).reshape(b, nc, qf, h)

    # intra-chunk: D[i,j] = prod_{j<t<=i} f_t * i_j
    cum = jnp.cumsum(log_f, axis=2)
    dif = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # [B,nc,Qi,Qj,H]
    mask = jnp.tril(jnp.ones((qf, qf), bool))
    dec = jnp.where(mask[None, None, :, :, None], jnp.exp(dif), 0.0)
    scores = jnp.einsum("bcihp,bcjhp->bcijh", qc, kc)
    w = scores * dec * ic[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, vc)
    # intra normalizer: q_i . (sum_j dec_ij i_j k_j) == sum_j w_ij
    nq_intra = jnp.sum(w, axis=3)                          # [B,nc,Q,H]

    # chunk state contributions
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)        # [B,nc,Q,H]
    c_state = jnp.einsum("bcjh,bcjhk,bcjhv->bchkv",
                         decay_to_end * ic, kc, vc)        # [B,nc,H,P,P]
    n_state = jnp.einsum("bcjh,bcjhk->bchk", decay_to_end * ic, kc)
    c_decay = jnp.exp(cum[:, :, -1, :])                    # [B,nc,H]

    if state is None:
        cmem = jnp.zeros((b, h, p, p), jnp.float32)
        nmem = jnp.zeros((b, h, p), jnp.float32)
    else:
        cmem, nmem = state

    def step(carry, inp):
        cm, nm = carry
        cs, ns, dc = inp
        out = (cm, nm)
        cm = cm * dc[:, :, None, None] + cs
        nm = nm * dc[:, :, None] + ns
        return (cm, nm), out

    (cmem, nmem), (c_init, n_init) = jax.lax.scan(
        step, (cmem, nmem),
        (jnp.moveaxis(c_state, 1, 0), jnp.moveaxis(n_state, 1, 0),
         jnp.moveaxis(c_decay, 1, 0)))
    c_init = jnp.moveaxis(c_init, 0, 1)                    # [B,nc,H,P,P]
    n_init = jnp.moveaxis(n_init, 0, 1)

    decay_from_start = jnp.exp(cum)
    y_inter = jnp.einsum("bcihk,bchkv,bcih->bcihv", qc, c_init, decay_from_start)
    n_inter = jnp.einsum("bcihk,bchk,bcih->bcih", qc, n_init, decay_from_start)

    y_all = (y_intra + y_inter)                            # [B,nc,Q,H,P]
    # |n·q| normalizer: running n vector dotted with q
    nq = nq_intra + n_inter
    denom = jnp.maximum(jnp.abs(nq), 1.0)[..., None]
    yv = (y_all / denom).reshape(b, s, h, p).reshape(b, s, d_in)
    yv = yv.astype(x.dtype)
    yv = rmsnorm(yv * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), params["norm"])
    return yv @ params["down"].astype(x.dtype), (cmem, nmem)


def mlstm_decode(cfg, params, x, state):
    """One-token mLSTM decode.  state = (C [B,H,P,P], n [B,H,P])."""
    d, d_in, h, p = _dims(cfg)
    b = x.shape[0]
    up = x @ params["up"].astype(x.dtype)
    xs, z = jnp.split(up, 2, axis=-1)
    q, k, v, i, f = _mlstm_qkvif(cfg, params, xs)
    qf = q[:, 0].astype(jnp.float32)                       # [B,H,P]
    kf = k[:, 0].astype(jnp.float32)
    vf = v[:, 0].astype(jnp.float32)
    i0, f0 = i[:, 0], f[:, 0]                              # [B,H]
    cmem, nmem = state
    cmem = cmem * f0[:, :, None, None] + i0[:, :, None, None] * jnp.einsum(
        "bhk,bhv->bhkv", kf, vf)
    nmem = nmem * f0[:, :, None] + i0[:, :, None] * kf
    y = jnp.einsum("bhk,bhkv->bhv", qf, cmem)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qf, nmem)), 1.0)
    y = (y / denom[:, :, None]).reshape(b, 1, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), params["norm"])
    return y @ params["down"].astype(x.dtype), (cmem, nmem)


def init_mlstm_state(cfg, batch: int):
    _, _, h, p = _dims(cfg)
    return (jnp.zeros((batch, h, p, p), jnp.float32),
            jnp.zeros((batch, h, p), jnp.float32))


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg):
    d, d_in, h, p = _dims(cfg)
    ks = jax.random.split(key, 10)
    return {
        "up": scaled_init(ks[0], (d, 2 * d_in), d),
        "wz": scaled_init(ks[1], (d_in, d_in), d_in),
        "wi": scaled_init(ks[2], (d_in, d_in), d_in),
        "wf": scaled_init(ks[3], (d_in, d_in), d_in),
        "wo": scaled_init(ks[4], (d_in, d_in), d_in),
        # block-diagonal recurrent weights, per head [H, P, P]
        "rz": scaled_init(ks[5], (h, p, p), p),
        "ri": scaled_init(ks[6], (h, p, p), p),
        "rf": scaled_init(ks[7], (h, p, p), p),
        "ro": scaled_init(ks[8], (h, p, p), p),
        "fb": jnp.full((d_in,), 3.0, jnp.float32),
        "norm": jnp.ones((d_in,), jnp.float32),
        "down": scaled_init(ks[9], (d_in, d), d_in),
    }


def _slstm_step(params, h_shape, carry, inp):
    """One sLSTM time step.  carry=(c,n,h,m) each [B,H,P] fp32."""
    hh, pp = h_shape
    c, n, hprev, m = carry
    xz, xi, xf, xo = inp                                   # [B,H,P] fp32 each

    def rec(r, hv):
        return jnp.einsum("bhp,hpq->bhq", hv, r.astype(jnp.float32))

    zt = jnp.tanh(xz + rec(params["rz"], hprev))
    it = xi + rec(params["ri"], hprev)
    ft = xf + rec(params["rf"], hprev)
    ot = jax.nn.sigmoid(xo + rec(params["ro"], hprev))
    m_new = jnp.maximum(ft + m, it)                        # stabilizer
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(ft + m - m_new)
    c = f_p * c + i_p * zt
    n = f_p * n + i_p
    hv = ot * c / jnp.maximum(n, 1.0)
    return (c, n, hv, m_new), hv


def slstm_forward(cfg, params, x, state=None):
    d, d_in, h, p = _dims(cfg)
    b, s, _ = x.shape
    up = x @ params["up"].astype(x.dtype)
    xs, z = jnp.split(up, 2, axis=-1)
    xz = (xs @ params["wz"].astype(x.dtype)).astype(jnp.float32)
    xi = (xs @ params["wi"].astype(x.dtype)).astype(jnp.float32)
    xf = ((xs @ params["wf"].astype(x.dtype)).astype(jnp.float32)
          + params["fb"])
    xo = (xs @ params["wo"].astype(x.dtype)).astype(jnp.float32)

    def rs(a):  # [B,S,Din] -> [S,B,H,P]
        return jnp.moveaxis(a.reshape(b, s, h, p), 1, 0)

    if state is None:
        state = init_slstm_state(cfg, b)
    (c, n, hv, m), ys = jax.lax.scan(
        lambda carry, inp: _slstm_step(params, (h, p), carry, inp),
        state, (rs(xz), rs(xi), rs(xf), rs(xo)))
    ys = jnp.moveaxis(ys, 0, 1).reshape(b, s, d_in).astype(x.dtype)
    ys = rmsnorm(ys * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), params["norm"])
    return ys @ params["down"].astype(x.dtype), (c, n, hv, m)


def slstm_decode(cfg, params, x, state):
    d, d_in, h, p = _dims(cfg)
    b = x.shape[0]
    up = x @ params["up"].astype(x.dtype)
    xs, z = jnp.split(up, 2, axis=-1)
    xs0 = xs[:, 0]
    xz = ((xs0 @ params["wz"].astype(x.dtype)).astype(jnp.float32)).reshape(b, h, p)
    xi = ((xs0 @ params["wi"].astype(x.dtype)).astype(jnp.float32)).reshape(b, h, p)
    xf = (((xs0 @ params["wf"].astype(x.dtype)).astype(jnp.float32)
           + params["fb"])).reshape(b, h, p)
    xo = ((xs0 @ params["wo"].astype(x.dtype)).astype(jnp.float32)).reshape(b, h, p)
    state, hv = _slstm_step(params, (h, p), state, (xz, xi, xf, xo))
    ys = hv.reshape(b, 1, d_in).astype(x.dtype)
    ys = rmsnorm(ys * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), params["norm"])
    return ys @ params["down"].astype(x.dtype), state


def init_slstm_state(cfg, batch: int):
    _, _, h, p = _dims(cfg)
    zero = jnp.zeros((batch, h, p), jnp.float32)
    return (zero, zero, zero, zero)
