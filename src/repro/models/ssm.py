"""Mamba2 (SSD — state-space duality) layer, TPU-adapted.

Chunked-scan formulation [arXiv:2405.21060, adapted]: the sequence is split
into chunks of Q tokens.  Within a chunk the recurrence is evaluated in its
quadratic "attention" dual (MXU-friendly matmuls, decays via masked segment
sums); across chunks a `lax.scan` carries the [H, P, N] state.  This is the
TPU-native adaptation of the CUDA selective-scan: no warp shuffles, just
matmuls shaped for the MXU and a short sequential scan over n_chunks.

Decode: O(1) recurrent state update per token (serve_step path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import scaled_init, rmsnorm


def init_mamba2(key, cfg):
    d = cfg.d_model
    s = cfg.ssm
    d_in = s.expand * d
    n_heads = max(1, d_in // s.head_dim)
    ks = jax.random.split(key, 6)
    conv_ch = d_in + 2 * s.state_size
    return {
        # order: [z | x | B | C | dt]
        "in_proj": scaled_init(ks[0], (d, 2 * d_in + 2 * s.state_size + n_heads), d),
        "conv_w": scaled_init(ks[1], (s.conv_width, conv_ch), s.conv_width),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "a_log": jnp.zeros((n_heads,), jnp.float32),       # A = -exp(a_log) = -1
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm": jnp.ones((d_in,), jnp.float32),
        "out_proj": scaled_init(ks[5], (d_in, d), d_in),
    }


def _split_in_proj(cfg, proj):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = max(1, d_in // s.head_dim)
    z, xin, b, c, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + s.state_size,
               2 * d_in + 2 * s.state_size], axis=-1)
    return z, xin, b, c, dt, d_in, n_heads


def _causal_conv(u, w, bias):
    """Depthwise causal conv.  u [B,S,C], w [K,C]."""
    k = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(u, dtype=jnp.float32)
    for i in range(k):
        out = out + pad[:, i: i + u.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out + bias.astype(jnp.float32)).astype(u.dtype)


def _segsum(log_a):
    """log_a [..., Q] -> decay matrix [..., Q, Q], L[i,j]=sum_{j<k<=i} log_a."""
    q = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    dif = cs[..., :, None] - cs[..., None, :]              # [..., i, j]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, dif, -jnp.inf)


def mamba2_forward(cfg, params, x, state=None):
    """Full-sequence SSD.  x [B,S,D] -> (y [B,S,D], final_state [B,H,P,N])."""
    s = cfg.ssm
    b_sz, seq, d = x.shape
    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(x.dtype))
    z, xin, bb, cc, dt, d_in, n_heads = _split_in_proj(cfg, proj)
    conv_in = jnp.concatenate([xin, bb, cc], axis=-1)
    conv_out = _causal_conv(conv_in, params["conv_w"], params["conv_b"])
    xin, bb, cc = jnp.split(conv_out, [d_in, d_in + s.state_size], axis=-1)

    p = s.head_dim
    h = n_heads
    xh = xin.reshape(b_sz, seq, h, p).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # [B,S,H]
    a = -jnp.exp(params["a_log"])                                      # [H]
    log_a = dt * a                                                     # [B,S,H]
    bbf = bb.astype(jnp.float32)
    ccf = cc.astype(jnp.float32)

    q = min(s.chunk_size, seq)
    nc = max(1, seq // q)
    assert nc * q == seq, f"seq {seq} not divisible by chunk {q}"
    xc = xh.reshape(b_sz, nc, q, h, p)
    dtc = dt.reshape(b_sz, nc, q, h)
    lac = log_a.reshape(b_sz, nc, q, h)
    bc = bbf.reshape(b_sz, nc, q, s.state_size)
    ccg = ccf.reshape(b_sz, nc, q, s.state_size)

    # ---- intra-chunk (quadratic dual) --------------------------------
    lmat = jnp.exp(_segsum(jnp.moveaxis(lac, -1, -2)))     # [B,nc,H,Q,Q]
    scores = jnp.einsum("bcin,bcjn->bcij", ccg, bc)        # [B,nc,Q,Q]
    dtx = xc * dtc[..., None]                              # [B,nc,Q,H,P]
    y_intra = jnp.einsum("bcij,bchij,bcjhp->bcihp",
                         scores, lmat, dtx)

    # ---- chunk states + inter-chunk scan -----------------------------
    cum = jnp.cumsum(lac, axis=2)                          # [B,nc,Q,H]
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)        # [B,nc,Q,H]
    chunk_state = jnp.einsum("bcjh,bcjhp,bcjn->bchpn",
                             decay_to_end, dtx, bc)        # [B,nc,H,P,N]
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # [B,nc,H]

    if state is None:
        state = jnp.zeros((b_sz, h, p, s.state_size), jnp.float32)

    def step(carry, inp):
        st = carry
        c_state, c_decay = inp
        out_state = st                                      # state BEFORE chunk
        st = st * c_decay[:, :, None, None] + c_state
        return st, out_state

    final_state, init_states = jax.lax.scan(
        step, state,
        (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    init_states = jnp.moveaxis(init_states, 0, 1)          # [B,nc,H,P,N]

    decay_from_start = jnp.exp(cum)                         # [B,nc,Q,H]
    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp",
                         ccg, init_states, decay_from_start)

    y = (y_intra + y_inter).reshape(b_sz, seq, h, p)
    y = y + xh * params["d_skip"][None, None, :, None]
    y = y.reshape(b_sz, seq, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), params["norm"])
    return jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(x.dtype)), final_state


def mamba2_decode(cfg, params, x, state, conv_state):
    """One-token decode.  x [B,1,D]; state [B,H,P,N]; conv_state [B,K-1,C]."""
    s = cfg.ssm
    b_sz, _, d = x.shape
    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(x.dtype))
    z, xin, bb, cc, dt, d_in, n_heads = _split_in_proj(cfg, proj)
    conv_in = jnp.concatenate([xin, bb, cc], axis=-1)      # [B,1,C]
    window = jnp.concatenate([conv_state, conv_in], axis=1)  # [B,K,C]
    w = params["conv_w"]
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          w.astype(jnp.float32)) + params["conv_b"]
    conv_out = jax.nn.silu(conv_out)[:, None, :].astype(x.dtype)
    new_conv_state = window[:, 1:]
    xin, bb, cc = jnp.split(conv_out, [d_in, d_in + s.state_size], axis=-1)

    p = s.head_dim
    xh = xin.reshape(b_sz, n_heads, p).astype(jnp.float32)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # [B,H]
    a = -jnp.exp(params["a_log"])
    decay = jnp.exp(dt * a)                                # [B,H]
    bbf = bb[:, 0].astype(jnp.float32)                     # [B,N]
    ccf = cc[:, 0].astype(jnp.float32)
    state = state * decay[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, bbf)
    y = jnp.einsum("bn,bhpn->bhp", ccf, state)
    y = y + xh * params["d_skip"][None, :, None]
    y = y.reshape(b_sz, 1, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), params["norm"])
    return (jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(x.dtype)),
            state, new_conv_state)


def init_mamba2_state(cfg, batch: int):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = max(1, d_in // s.head_dim)
    conv_ch = d_in + 2 * s.state_size
    return (jnp.zeros((batch, n_heads, s.head_dim, s.state_size), jnp.float32),
            jnp.zeros((batch, s.conv_width - 1, conv_ch), jnp.bfloat16))
