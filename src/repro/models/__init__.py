from repro.models.model import Model, ModelOutputs
from repro.models.ffn import ShardCtx, SINGLE

__all__ = ["Model", "ModelOutputs", "ShardCtx", "SINGLE"]
