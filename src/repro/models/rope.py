"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE splits the rotary half-dim into (temporal, height, width) sections and
rotates each section by its own position component [arXiv:2409.12191].  For
text-only positions all three components are equal, which reduces M-RoPE to
RoPE exactly — the property our tests assert.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# fraction of the rotary half-dim given to (t, h, w) sections
MROPE_SECTIONS = (0.25, 0.375, 0.375)


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def _angles(positions, head_dim: int, theta: float):
    """positions [..., S] -> angles [..., S, half]."""
    inv = rope_freqs(head_dim, theta)
    return positions[..., None].astype(jnp.float32) * inv


def apply_rope(x, positions, theta: float = 10_000.0):
    """x [B, S, N, H], positions [B, S] (or [S]) -> rotated x."""
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = _angles(positions, x.shape[-1], theta)          # [B, S, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[:, :, None, :]                               # [B, S, 1, half]
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mrope_angles(positions3, head_dim: int, theta: float):
    """positions3 [3, B, S] (t, h, w) -> angles [B, S, half] with sections."""
    half = head_dim // 2
    n_t = int(half * MROPE_SECTIONS[0])
    n_h = int(half * MROPE_SECTIONS[1])
    n_w = half - n_t - n_h
    inv = rope_freqs(head_dim, theta)
    ang_all = positions3[..., None].astype(jnp.float32) * inv  # [3, B, S, half]
    return jnp.concatenate(
        [ang_all[0, ..., :n_t], ang_all[1, ..., n_t:n_t + n_h], ang_all[2, ..., n_t + n_h:]],
        axis=-1,
    )


def apply_mrope(x, positions3, theta: float = 1_000_000.0):
    """x [B, S, N, H], positions3 [3, B, S]."""
    ang = mrope_angles(positions3, x.shape[-1], theta)     # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_positional(x, positions, kind: str, theta: float):
    """Dispatch: kind in {rope, mrope, none}.

    For mrope, `positions` may be [B, S] (text-only: broadcast to 3 equal
    components) or [3, B, S].
    """
    if kind == "none":
        return x
    if kind == "mrope":
        if positions.ndim != 3:
            if positions.ndim == 1:
                positions = positions[None, :]
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        return apply_mrope(x, positions, theta)
    return apply_rope(x, positions, theta)
