"""Shared building blocks: norms, activations, initializers, embedding."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Initializers (all params created through these for deterministic trees)
# ---------------------------------------------------------------------------

def normal_init(key, shape, std: float = 0.02, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def scaled_init(key, shape, fan_in: int, dtype=jnp.float32):
    std = 1.0 / np.sqrt(max(1, fan_in))
    return normal_init(key, shape, std=std, dtype=dtype)


def zeros_init(_key, shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# Norms — computed in fp32, cast back
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def layernorm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def apply_norm(kind: str, x, p):
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


def init_norm(kind: str, key, d: int):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def activation(kind: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[kind]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed(tokens, table):
    return jnp.take(table, tokens, axis=0)


def unembed(x, table):
    """x [..., D] @ table.T [D, V] -> logits fp32."""
    return jnp.einsum("...d,vd->...v", x, table).astype(jnp.float32)


def softmax_cross_entropy(logits, labels, mask=None):
    """Mean CE over valid positions.  logits fp32 [..., V], labels int [...]."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = logz - gold
    if mask is not None:
        loss = loss * mask
        return jnp.sum(loss) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(loss)
