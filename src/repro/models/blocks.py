"""Execution plan + block (layer-group) implementations.

Every architecture compiles to a PLAN: an ordered list of steps

    ("scan",  kind, n_units, layer0)   — lax.scan over n_units stacked layers
    ("shared_attn", site_idx)          — zamba2 weight-shared attention block
    ("exit", exit_idx, layer)          — early-exit head / partition boundary

Scan kinds: dense | moe | pair | mamba | mlstm | slstm | decx | enc.
Plan boundaries are exactly the survey's partition points: tier placement,
early exits and failure bypasses all operate on plan steps (core/*).
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import apply_norm, init_norm, scaled_init
from repro.models.ffn import ShardCtx, SINGLE


# ---------------------------------------------------------------------------
# Plan construction
# ---------------------------------------------------------------------------

def layer_kind(cfg, i: int) -> str:
    if cfg.family in ("dense", "vlm"):
        return "dense"
    if cfg.family == "moe":
        m = cfg.moe
        if i < m.first_dense_layers:
            return "dense"
        if m.layer_period > 1:
            return "pair"              # grouped (dense, moe) unit
        return "moe"
    if cfg.family == "hybrid":
        return "mamba"
    if cfg.family == "ssm":
        return "slstm" if i in cfg.ssm.slstm_layers else "mlstm"
    if cfg.family == "encdec":
        return "decx"
    raise ValueError(cfg.family)


def shared_attn_sites(cfg) -> Tuple[int, ...]:
    if not cfg.shared_attn_period:
        return ()
    p = cfg.shared_attn_period
    return tuple(i for i in range(cfg.num_layers) if i % p == p - 1)


def build_plan(cfg) -> List[Tuple]:
    """Returns the ordered plan (see module docstring)."""
    L = cfg.num_layers
    exits = set(cfg.exits.exit_layers)
    sa = set(i + 1 for i in shared_attn_sites(cfg))        # boundary AFTER site
    # boundaries where a scan must break
    bounds = {0, L} | exits | sa
    for i in range(1, L):
        if layer_kind(cfg, i) != layer_kind(cfg, i - 1):
            bounds.add(i)
    if cfg.family == "moe" and cfg.moe.layer_period > 1:
        # pair units must not be split mid-unit
        period = cfg.moe.layer_period
        bounds = {b for b in bounds
                  if b <= cfg.moe.first_dense_layers or (b - cfg.moe.first_dense_layers) % period == 0
                  or b == L}
    bl = sorted(bounds)
    plan: List[Tuple] = []
    exit_idx = 0
    sa_idx = 0
    for a, b in zip(bl[:-1], bl[1:]):
        kind = layer_kind(cfg, a)
        n = b - a
        if kind == "pair":
            n = n // cfg.moe.layer_period
        plan.append(("scan", kind, n, a))
        if b in sa:
            plan.append(("shared_attn", sa_idx))
            sa_idx += 1
        if b in exits:
            plan.append(("exit", exit_idx, b))
            exit_idx += 1
    return plan


# ---------------------------------------------------------------------------
# Per-layer init by kind
# ---------------------------------------------------------------------------

def _init_dense_layer(key, cfg):
    ks = jax.random.split(key, 4)
    return {
        "ln1": init_norm(cfg.norm, ks[0], cfg.d_model),
        "attn": attn.init_attention(ks[1], cfg),
        "ln2": init_norm(cfg.norm, ks[2], cfg.d_model),
        "ffn": ffn_mod.init_ffn(ks[3], cfg.d_model, cfg.d_ff, cfg.act),
    }


def _init_moe_layer(key, cfg):
    ks = jax.random.split(key, 4)
    return {
        "ln1": init_norm(cfg.norm, ks[0], cfg.d_model),
        "attn": attn.init_attention(ks[1], cfg),
        "ln2": init_norm(cfg.norm, ks[2], cfg.d_model),
        "moe": ffn_mod.init_moe(ks[3], cfg),
    }


def _init_pair_unit(key, cfg):
    ka, kb = jax.random.split(key)
    return {"a": _init_dense_layer(ka, cfg), "b": _init_moe_layer(kb, cfg)}


def _init_mamba_layer(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"ln": init_norm(cfg.norm, k1, cfg.d_model),
            "mamba": ssm_mod.init_mamba2(k2, cfg)}


def _init_mlstm_layer(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"ln": init_norm(cfg.norm, k1, cfg.d_model),
            "mlstm": xlstm_mod.init_mlstm(k2, cfg)}


def _init_slstm_layer(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"ln": init_norm(cfg.norm, k1, cfg.d_model),
            "slstm": xlstm_mod.init_slstm(k2, cfg)}


def _init_decx_layer(key, cfg):
    ks = jax.random.split(key, 6)
    return {
        "ln1": init_norm(cfg.norm, ks[0], cfg.d_model),
        "self_attn": attn.init_gqa(ks[1], cfg),
        "ln2": init_norm(cfg.norm, ks[2], cfg.d_model),
        "cross_attn": attn.init_gqa(ks[3], cfg),
        "ln3": init_norm(cfg.norm, ks[4], cfg.d_model),
        "ffn": ffn_mod.init_ffn(ks[5], cfg.d_model, cfg.d_ff, cfg.act),
    }


_INIT = {
    "dense": _init_dense_layer, "moe": _init_moe_layer, "pair": _init_pair_unit,
    "mamba": _init_mamba_layer, "mlstm": _init_mlstm_layer,
    "slstm": _init_slstm_layer, "decx": _init_decx_layer,
    "enc": _init_dense_layer,
}


def init_scan_block(key, cfg, kind: str, n_units: int):
    """Stacked params [n_units, ...] for a scanned block."""
    keys = jax.random.split(key, n_units)
    layers = [_INIT[kind](k, cfg) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


def init_shared_attn(key, cfg):
    """zamba2 shared block: attention + FFN with own norms (ONE set of weights)."""
    ks = jax.random.split(key, 4)
    return {
        "ln1": init_norm(cfg.norm, ks[0], cfg.d_model),
        "attn": attn.init_gqa(ks[1], cfg),
        "ln2": init_norm(cfg.norm, ks[2], cfg.d_model),
        "ffn": ffn_mod.init_ffn(ks[3], cfg.d_model, cfg.d_ff, cfg.act),
    }


def init_exit_head(key, cfg):
    k1, k2 = jax.random.split(key)
    hid = cfg.exits.head_hidden
    p = {"norm": init_norm(cfg.norm, k1, cfg.d_model)}
    if hid:
        p["w_h"] = scaled_init(k1, (cfg.d_model, hid), cfg.d_model)
        p["w"] = scaled_init(k2, (hid, cfg.vocab_size), hid)
    else:
        p["w"] = scaled_init(k2, (cfg.d_model, cfg.vocab_size), cfg.d_model)
    return p


def exit_head_hidden(cfg, p, x):
    """The exit head's pre-vocab hidden state (norm + optional gelu MLP) —
    shared by the full-logits head and the fused entropy probe so the two
    paths cannot drift."""
    h = apply_norm(cfg.norm, x, p["norm"])
    if "w_h" in p:
        h = jax.nn.gelu(h @ p["w_h"].astype(h.dtype))
    return h


def exit_head_logits(cfg, p, x):
    h = exit_head_hidden(cfg, p, x)
    return jnp.einsum("...d,dv->...v", h, p["w"].astype(h.dtype)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Forward (full sequence) per kind
# ---------------------------------------------------------------------------

def _dense_fwd(cfg, lp, x, positions, window, ctx, causal=True):
    h = apply_norm(cfg.norm, x, lp["ln1"])
    if cfg.attention == "mla":
        y, _ = attn.mla_forward(cfg, lp["attn"], h, positions, causal=causal,
                                window=window)
    else:
        y, _ = attn.gqa_forward(cfg, lp["attn"], h, positions, causal=causal,
                                window=window)
    x = x + y
    h = apply_norm(cfg.norm, x, lp["ln2"])
    return x + ffn_mod.ffn_forward(lp["ffn"], h, cfg.act), jnp.float32(0.0)


def _moe_fwd(cfg, lp, x, positions, window, ctx):
    h = apply_norm(cfg.norm, x, lp["ln1"])
    if cfg.attention == "mla":
        y, _ = attn.mla_forward(cfg, lp["attn"], h, positions, window=window)
    else:
        y, _ = attn.gqa_forward(cfg, lp["attn"], h, positions, window=window)
    x = x + y
    h = apply_norm(cfg.norm, x, lp["ln2"])
    y, aux = ffn_mod.moe_ffn(lp["moe"], h, cfg, ctx)
    return x + y, aux


def _pair_fwd(cfg, lp, x, positions, window, ctx):
    x, _ = _dense_fwd(cfg, lp["a"], x, positions, window, ctx)
    return _moe_fwd(cfg, lp["b"], x, positions, window, ctx)


def _mamba_fwd(cfg, lp, x, positions, window, ctx):
    h = apply_norm(cfg.norm, x, lp["ln"])
    y, _ = ssm_mod.mamba2_forward(cfg, lp["mamba"], h)
    return x + y, jnp.float32(0.0)


def _mlstm_fwd(cfg, lp, x, positions, window, ctx):
    h = apply_norm(cfg.norm, x, lp["ln"])
    y, _ = xlstm_mod.mlstm_forward(cfg, lp["mlstm"], h)
    return x + y, jnp.float32(0.0)


def _slstm_fwd(cfg, lp, x, positions, window, ctx):
    h = apply_norm(cfg.norm, x, lp["ln"])
    y, _ = xlstm_mod.slstm_forward(cfg, lp["slstm"], h)
    return x + y, jnp.float32(0.0)


def _make_decx_fwd(enc_out):
    def f(cfg, lp, x, positions, window, ctx):
        h = apply_norm(cfg.norm, x, lp["ln1"])
        y, _ = attn.gqa_forward(cfg, lp["self_attn"], h, positions, causal=True,
                                window=window)
        x = x + y
        h = apply_norm(cfg.norm, x, lp["ln2"])
        y, _ = attn.gqa_forward(cfg, lp["cross_attn"], h, positions, kv_x=enc_out)
        x = x + y
        h = apply_norm(cfg.norm, x, lp["ln3"])
        return x + ffn_mod.ffn_forward(lp["ffn"], h, cfg.act), jnp.float32(0.0)
    return f


def _enc_fwd(cfg, lp, x, positions, window, ctx):
    return _dense_fwd(cfg, lp, x, positions, window, ctx, causal=False)


def run_scan_block(cfg, kind: str, bparams, x, positions, window, ctx,
                   enc_out=None, remat: bool = False):
    """Scan a stacked block over its layers.  Returns (x, aux_sum).

    remat=True wraps the per-layer body in jax.checkpoint (activation
    rematerialization) — used by the training path so the backward pass
    re-computes layer internals instead of saving them.
    """
    fwd = {
        "dense": _dense_fwd, "moe": _moe_fwd, "pair": _pair_fwd,
        "mamba": _mamba_fwd, "mlstm": _mlstm_fwd, "slstm": _slstm_fwd,
        "decx": _make_decx_fwd(enc_out), "enc": _enc_fwd,
    }[kind]

    def layer(lp, xx):
        return fwd(cfg, lp, xx, positions, window, ctx)

    if remat:
        layer = jax.checkpoint(layer)

    def body(carry, lp):
        xx, aux = layer(lp, carry)
        return xx, aux

    n = jax.tree.leaves(bparams)[0].shape[0]
    if n == 1:
        lp = jax.tree.map(lambda a: a[0], bparams)
        x, aux = layer(lp, x)
        return x, aux
    x, auxs = jax.lax.scan(body, x, bparams)
    return x, jnp.sum(auxs)


def run_shared_attn(cfg, sp, x, positions, window):
    h = apply_norm(cfg.norm, x, sp["ln1"])
    y, _ = attn.gqa_forward(cfg, sp["attn"], h, positions, causal=True,
                            window=window)
    x = x + y
    h = apply_norm(cfg.norm, x, sp["ln2"])
    return x + ffn_mod.ffn_forward(sp["ffn"], h, cfg.act)


# ---------------------------------------------------------------------------
# Decode (single token, cache-carrying) per kind
# ---------------------------------------------------------------------------

# Scan kinds whose decode cache is attention KV (paged-arena eligible).
# State kinds (mamba/mlstm/slstm) keep fixed per-slot rows; decx/enc never
# reach paged decode (scheduler asserts family != "encdec" in paged mode).
PAGED_KINDS = frozenset({"dense", "moe", "pair", "enc"})


def init_layer_cache_paged(cfg, kind: str, batch: int, n_pages: int,
                           page_size: int):
    """Paged decode cache for ONE layer: attention leaves become global
    pools ``[n_pages, P, ...]`` (indexed via the slot block table); state
    kinds keep their per-slot rows unchanged."""
    hd = cfg.resolved_head_dim
    nkv = cfg.num_kv_heads
    kv = lambda: (jnp.zeros((n_pages, page_size, nkv, hd), jnp.bfloat16),
                  jnp.zeros((n_pages, page_size, nkv, hd), jnp.bfloat16))
    if kind in ("dense", "enc", "moe"):
        if cfg.attention == "mla":
            return (jnp.zeros((n_pages, page_size, cfg.kv_lora_rank),
                              jnp.bfloat16),
                    jnp.zeros((n_pages, page_size, cfg.qk_rope_head_dim),
                              jnp.bfloat16))
        return kv()
    if kind == "pair":
        return {"a": init_layer_cache_paged(cfg, "dense", batch, n_pages,
                                            page_size),
                "b": init_layer_cache_paged(cfg, "moe", batch, n_pages,
                                            page_size)}
    if kind in ("mamba", "mlstm", "slstm"):
        return init_layer_cache(cfg, kind, batch, 0)
    raise ValueError(f"kind {kind!r} has no paged decode cache")


def init_layer_cache(cfg, kind: str, batch: int, cache_len: int):
    """Decode cache for ONE layer of the given kind."""
    hd = cfg.resolved_head_dim
    nkv = cfg.num_kv_heads
    kv = lambda: (jnp.zeros((batch, cache_len, nkv, hd), jnp.bfloat16),
                  jnp.zeros((batch, cache_len, nkv, hd), jnp.bfloat16))
    if kind in ("dense", "enc"):
        if cfg.attention == "mla":
            return (jnp.zeros((batch, cache_len, cfg.kv_lora_rank), jnp.bfloat16),
                    jnp.zeros((batch, cache_len, cfg.qk_rope_head_dim), jnp.bfloat16))
        return kv()
    if kind == "moe":
        if cfg.attention == "mla":
            return (jnp.zeros((batch, cache_len, cfg.kv_lora_rank), jnp.bfloat16),
                    jnp.zeros((batch, cache_len, cfg.qk_rope_head_dim), jnp.bfloat16))
        return kv()
    if kind == "pair":
        return {"a": init_layer_cache(cfg, "dense", batch, cache_len),
                "b": init_layer_cache(cfg, "moe", batch, cache_len)}
    if kind == "mamba":
        return ssm_mod.init_mamba2_state(cfg, batch)
    if kind == "mlstm":
        return xlstm_mod.init_mlstm_state(cfg, batch)
    if kind == "slstm":
        return xlstm_mod.init_slstm_state(cfg, batch)
    if kind == "decx":
        enc_len = cfg.encdec.encoder_seq_len
        return {"self": kv(),
                "cross": (jnp.zeros((batch, enc_len, nkv, hd), jnp.bfloat16),
                          jnp.zeros((batch, enc_len, nkv, hd), jnp.bfloat16))}
    raise ValueError(kind)


def _attn_decode_dispatch(cfg, lp_attn, h, cache, position, window, paged=None):
    if paged is not None:
        if cfg.attention == "mla":
            return attn.mla_decode_paged(cfg, lp_attn, h, cache[0], cache[1],
                                         position, paged)
        return attn.gqa_decode_paged(cfg, lp_attn, h, cache[0], cache[1],
                                     position, paged)
    if cfg.attention == "mla":
        y, new = attn.mla_decode(cfg, lp_attn, h, cache[0], cache[1], position,
                                 window=window)
    else:
        y, new = attn.gqa_decode(cfg, lp_attn, h, cache[0], cache[1], position,
                                 window=window)
    return y, new


def decode_layer(cfg, kind: str, lp, x, cache, position, window, ctx,
                 paged=None):
    """One-token decode through one layer.  Returns (x, new_cache, aux).

    paged != None (an ``attn.PagedKV``): attention caches are paged pools;
    state kinds are unaffected (their write gating lives in the caller's
    merge)."""
    zero = jnp.float32(0.0)
    if kind in ("dense", "enc"):
        h = apply_norm(cfg.norm, x, lp["ln1"])
        y, new = _attn_decode_dispatch(cfg, lp["attn"], h, cache, position,
                                       window, paged)
        x = x + y
        h = apply_norm(cfg.norm, x, lp["ln2"])
        return x + ffn_mod.ffn_forward(lp["ffn"], h, cfg.act), new, zero
    if kind == "moe":
        h = apply_norm(cfg.norm, x, lp["ln1"])
        y, new = _attn_decode_dispatch(cfg, lp["attn"], h, cache, position,
                                       window, paged)
        x = x + y
        h = apply_norm(cfg.norm, x, lp["ln2"])
        y, aux = ffn_mod.moe_ffn(lp["moe"], h, cfg, ctx)
        return x + y, new, aux
    if kind == "pair":
        x, new_a, _ = decode_layer(cfg, "dense", lp["a"], x, cache["a"], position,
                                   window, ctx, paged)
        x, new_b, aux = decode_layer(cfg, "moe", lp["b"], x, cache["b"], position,
                                     window, ctx, paged)
        return x, {"a": new_a, "b": new_b}, aux
    if kind == "mamba":
        h = apply_norm(cfg.norm, x, lp["ln"])
        y, st, cv = ssm_mod.mamba2_decode(cfg, lp["mamba"], h, cache[0], cache[1])
        return x + y, (st, cv), zero
    if kind == "mlstm":
        h = apply_norm(cfg.norm, x, lp["ln"])
        y, new = xlstm_mod.mlstm_decode(cfg, lp["mlstm"], h, cache)
        return x + y, new, zero
    if kind == "slstm":
        h = apply_norm(cfg.norm, x, lp["ln"])
        y, new = xlstm_mod.slstm_decode(cfg, lp["slstm"], h, cache)
        return x + y, new, zero
    if kind == "decx":
        h = apply_norm(cfg.norm, x, lp["ln1"])
        y, new_self = attn.gqa_decode(cfg, lp["self_attn"], h, cache["self"][0],
                                      cache["self"][1], position, window=window)
        x = x + y
        h = apply_norm(cfg.norm, x, lp["ln2"])
        y = attn.cross_decode(cfg, lp["cross_attn"], h, cache["cross"][0],
                              cache["cross"][1])
        x = x + y
        h = apply_norm(cfg.norm, x, lp["ln3"])
        return (x + ffn_mod.ffn_forward(lp["ffn"], h, cfg.act),
                {"self": new_self, "cross": cache["cross"]}, zero)
    raise ValueError(kind)


def decode_scan_block(cfg, kind: str, bparams, x, caches, position, window, ctx,
                      paged=None):
    """Decode through a stacked block, scanning layers with per-layer caches.

    Paged attention caches are stacked pools ``[n, n_pages, P, ...]`` — the
    scan unstacks the layer axis exactly like contiguous rows; the block
    table (inside ``paged``) is shared across layers."""
    n = jax.tree.leaves(bparams)[0].shape[0]
    if n == 1:
        lp = jax.tree.map(lambda a: a[0], bparams)
        cc = jax.tree.map(lambda a: a[0], caches)
        x, new, aux = decode_layer(cfg, kind, lp, x, cc, position, window, ctx,
                                   paged)
        return x, jax.tree.map(lambda a: a[None], new), aux

    def body(carry, inp):
        xx = carry
        lp, cc = inp
        xx, new, aux = decode_layer(cfg, kind, lp, xx, cc, position, window,
                                    ctx, paged)
        return xx, (new, aux)

    x, (new_caches, auxs) = jax.lax.scan(body, x, (bparams, caches))
    return x, new_caches, jnp.sum(auxs)


def run_shared_attn_decode(cfg, sp, x, cache, position, window, paged=None):
    h = apply_norm(cfg.norm, x, sp["ln1"])
    if paged is not None:
        y, new = attn.gqa_decode_paged(cfg, sp["attn"], h, cache[0], cache[1],
                                       position, paged)
    else:
        y, new = attn.gqa_decode(cfg, sp["attn"], h, cache[0], cache[1],
                                 position, window=window)
    x = x + y
    h = apply_norm(cfg.norm, x, sp["ln2"])
    return x + ffn_mod.ffn_forward(sp["ffn"], h, cfg.act), new
