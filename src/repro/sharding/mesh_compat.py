"""Version-tolerant AbstractMesh construction.

JAX changed ``AbstractMesh``'s constructor across releases:

* older releases:  ``AbstractMesh(shape_tuple, axis_names)`` with
  ``shape_tuple = (16, 16)`` and ``axis_names = ("data", "model")``
* current releases: ``AbstractMesh((("data", 16), ("model", 16)))`` — one
  tuple of (name, size) pairs (optionally followed by axis_types).

``make_abstract_mesh(sizes, names)`` accepts the split form and builds the
mesh under whichever signature the installed JAX exposes, so sharding-rule
tests don't break on a JAX upgrade.
"""
from __future__ import annotations

from typing import Sequence

from jax.sharding import AbstractMesh


def make_abstract_mesh(sizes: Sequence[int], names: Sequence[str]) -> AbstractMesh:
    """AbstractMesh from parallel (sizes, names), e.g. ((16, 16), ("data",
    "model")), tolerant to the installed JAX's constructor signature."""
    assert len(sizes) == len(names), (sizes, names)
    try:                                   # current API: ((name, size), ...)
        return AbstractMesh(tuple(zip(names, sizes)))
    except (TypeError, ValueError):        # legacy API: (sizes, names)
        return AbstractMesh(tuple(sizes), tuple(names))
