"""Partition specs for params / optimizer state / batches / decode caches.

Rule-based: every leaf gets a PartitionSpec from its tree path + shape.
The baseline scheme (hillclimbed in EXPERIMENTS.md §Perf):

- "model" axis: tensor parallel — attention heads, FFN width, MoE experts,
  vocab.  When a head count is not divisible by the axis (GQA kv-heads), we
  fall back to sharding the contraction (d_model) dim, which the SPMD
  partitioner turns into a reduce-scatter/psum pair.
- ("pod","data") axes: batch for activations; ZeRO-1 for optimizer moments
  (m/v additionally sharded over data on the first free divisible dim).
- decode caches: batch over "data"; the sequence dim over "model" when the
  kv-head dim cannot shard (context-parallel cache).
"""
from __future__ import annotations

import math
from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P
from jax.tree_util import tree_map_with_path, DictKey, SequenceKey


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if isinstance(k, DictKey):
            out.append(str(k.key))
        elif isinstance(k, SequenceKey):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return tuple(out)


def _div(n: int, size: int) -> bool:
    return size > 0 and n % size == 0 and n >= size


class ShardingRules:
    """strategy:
    - "tp" (baseline): model axis = tensor parallel (heads/ffn/experts/vocab)
    - "dp_zero": weights replicated over the model axis, batch sharded over
      (pod, data, model), optimizer moments ZeRO-sharded over ALL axes.
      Beyond-paper profile for small dense models where TP's per-layer
      activation collectives dominate (EXPERIMENTS.md §Perf).
    """

    def __init__(self, mesh, strategy: str = "tp"):
        self.mesh = mesh
        self.strategy = strategy
        self.axes = mesh.axis_names
        self.model = ("model" if "model" in self.axes and strategy == "tp"
                      else None)
        self.msize = mesh.shape["model"] if self.model else 1
        if strategy == "dp_zero":
            self.data_axes = tuple(a for a in ("pod", "data", "model")
                                   if a in self.axes)
        else:
            self.data_axes = tuple(a for a in ("pod", "data") if a in self.axes)
        self.dsize = math.prod(mesh.shape[a] for a in self.data_axes) or 1

    # ------------------------------------------------------------------
    def _spec(self, ndim: int, **placed) -> P:
        parts = [None] * ndim
        for dim, axis in placed.items():
            parts[int(dim)] = axis
        return P(*parts)

    def param_spec(self, path: Tuple[str, ...], shape: Tuple[int, ...]) -> P:
        name = path[-1] if path else ""
        nd = len(shape)
        m, ms = self.model, self.msize
        if m is None or nd == 0:
            return P()
        in_exit = "exit_heads" in path
        stack = 1 if (path and path[0] == "blocks") or "layer" in path else 0

        def last_if_div(*dims):
            for d in dims:
                d = d % nd
                if _div(shape[d], ms):
                    return self._spec(nd, **{str(d): m})
            return P(*([None] * nd))

        if name in ("embed", "lm_head"):
            return last_if_div(0, 1)
        if in_exit and name == "w":
            return last_if_div(nd - 1, 0)
        if name in ("w_gate", "w_up", "w_in", "w_h"):
            return last_if_div(nd - 1)
        if name == "w_down":
            return last_if_div(nd - 2)
        if name in ("wg", "wu", "wd",                   # MoE experts [*,E,.,.]
                    "wg_q", "wu_q", "wd_q", "wg_s", "wu_s", "wd_s"):
            return last_if_div(nd - 3)
        if name == "router":
            return P(*([None] * nd))
        if name == "wq" and nd - stack == 3:            # attn q [*,D,Nq,H]
            return last_if_div(nd - 2, nd - 3)
        if name in ("wk", "wv") and nd - stack == 3:    # GQA kv: heads or D
            return last_if_div(nd - 2, nd - 3)
        if name == "wo" and nd - stack == 3:            # [*,Nq,H,D]
            return last_if_div(nd - 3, nd - 1)
        if name in ("wq_b", "wk_b", "wv_b"):            # MLA [*,R,Nq,h]
            return last_if_div(nd - 2)
        if name in ("wq_a", "wkv_a"):
            return last_if_div(nd - 1)
        if name == "in_proj":                           # mamba [*,D,X]
            return last_if_div(nd - 1)
        if name == "out_proj":
            return last_if_div(nd - 2)
        if name == "up":                                # xlstm [*,D,2Din]
            return last_if_div(nd - 1)
        if name == "down":
            return last_if_div(nd - 2)
        if name in ("wq", "wk", "wv", "wz", "wi", "wf", "wo") and nd - stack == 2:
            return last_if_div(nd - 1)                  # xlstm projections
        if name == "combine":
            return last_if_div(nd - 1)
        return P(*([None] * nd))

    def opt_moment_spec(self, pspec: P, shape: Tuple[int, ...]) -> P:
        """ZeRO-1: add the data axes on the first free divisible dim."""
        if not self.data_axes:
            return pspec
        parts = list(pspec) + [None] * (len(shape) - len(pspec))
        for i, (p, n) in enumerate(zip(parts, shape)):
            if p is None and _div(n, self.dsize):
                parts[i] = self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]
                return P(*parts)
        return pspec

    # ------------------------------------------------------------------
    def params_specs(self, params_shapes):
        return tree_map_with_path(
            lambda path, leaf: self.param_spec(_path_names(path), leaf.shape),
            params_shapes)

    def opt_specs(self, opt_shapes, params_shapes):
        pspecs = self.params_specs(params_shapes)
        mspec = jax.tree.map(
            lambda sp, leaf: self.opt_moment_spec(sp, leaf.shape),
            pspecs, params_shapes)
        return {"m": mspec, "v": jax.tree.map(lambda s: s, mspec),
                "step": P()}

    def batch_specs(self, batch_shapes):
        """Shard batch over as many data axes as divisibility allows
        (dp_zero on 512 chips with batch 256 falls back to 32-way)."""
        candidates = []
        axes = list(self.data_axes)
        while axes:
            candidates.append(tuple(axes))
            axes = axes[:-1]

        def spec(path, leaf):
            b = leaf.shape[0] if leaf.ndim else 1
            for cand in candidates:
                size = math.prod(self.mesh.shape[a] for a in cand)
                if _div(b, size):
                    ax = cand if len(cand) > 1 else cand[0]
                    return P(ax, *([None] * (leaf.ndim - 1)))
            return P(*([None] * leaf.ndim))

        return tree_map_with_path(spec, batch_shapes)

    def cache_specs(self, cache_shapes):
        """Decode caches: dim0 = stacked layers, dim1 = batch, then per-kind.

        5D [n, B, S, nkv, hd]: shard kv-heads over model when divisible,
        else the SEQUENCE dim (context-parallel cache).
        4D [n, B, S, R] (MLA latent / k_rope): shard the SEQUENCE dim over
        model — sharding R would force a per-layer cache all-gather for the
        q·c contraction (EXPERIMENTS.md §Perf deepseek iteration).
        3D/recurrent states: shard the widest trailing dim if divisible.
        """
        data = "data" if "data" in self.axes else None
        m, ms = self.model, self.msize

        def spec(path, leaf):
            nd = leaf.ndim
            names = _path_names(path)
            parts = [None] * nd
            if "shared_attn" in names:
                # unstacked [B, S, nkv, hd] (zamba2 weight-shared block)
                if data and _div(leaf.shape[0], self.mesh.shape["data"]):
                    parts[0] = data
                if m is not None and nd == 4:
                    if _div(leaf.shape[2], ms):
                        parts[2] = m
                    elif _div(leaf.shape[1], ms) and leaf.shape[1] >= 1024:
                        parts[1] = m
                return P(*parts)
            if nd >= 2 and data and _div(leaf.shape[1], self.mesh.shape["data"]):
                parts[1] = data
            if m is None:
                return P(*parts)
            if nd == 5:
                if _div(leaf.shape[3], ms):
                    parts[3] = m
                elif _div(leaf.shape[2], ms) and leaf.shape[2] >= 1024:
                    parts[2] = m
            elif nd == 4:
                if _div(leaf.shape[2], ms) and leaf.shape[2] >= 1024:
                    parts[2] = m        # sequence (context-parallel)
                elif _div(leaf.shape[3], ms) and leaf.shape[3] >= 128:
                    parts[3] = m
            elif nd == 3 and _div(leaf.shape[2], ms) and leaf.shape[2] >= 128:
                parts[2] = m
            return P(*parts)

        return tree_map_with_path(spec, cache_shapes)
