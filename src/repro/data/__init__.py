from repro.data.pipeline import DataConfig, lm_batch, batch_for_model, data_iterator

__all__ = ["DataConfig", "lm_batch", "batch_for_model", "data_iterator"]
