"""Synthetic data pipeline: deterministic, stateless, shardable.

Batches are a pure function of (config, shape, step) — every host computes
its shard without coordination, which is exactly what a multi-pod input
pipeline needs.  Two sources:

- `lm_batch`: Zipf-distributed token stream with a copy-structure (spans
  repeated at a fixed lag) so language-model training has real signal and
  the loss visibly drops in the examples.
- frontend stubs: `patch_embeds` (vlm) / `frames` (encdec) as the
  precomputed modality embeddings required by the brief.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    copy_lag: int = 32            # tokens repeat with this lag (learnable signal)
    copy_prob: float = 0.5
    zipf_a: float = 1.2


def _zipf_logits(vocab: int, a: float):
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    return -a * jnp.log(ranks)


def lm_batch(cfg: DataConfig, step: int, *, d_model: int = 0,
             frontend: str = "none", frontend_tokens: int = 0) -> Dict[str, jnp.ndarray]:
    """One global batch.  tokens/labels [B, S] int32 (+ stub embeddings)."""
    key = jax.random.PRNGKey(step)
    k1, k2, k3 = jax.random.split(key, 3)
    logits = _zipf_logits(cfg.vocab_size, cfg.zipf_a)
    toks = jax.random.categorical(
        k1, jnp.broadcast_to(logits, (cfg.global_batch, cfg.seq_len, cfg.vocab_size)))
    # inject copy structure: with copy_prob, token[t] = token[t - lag]
    lag = min(cfg.copy_lag, cfg.seq_len - 1)
    copy_mask = jax.random.bernoulli(k2, cfg.copy_prob,
                                     (cfg.global_batch, cfg.seq_len))
    rolled = jnp.roll(toks, lag, axis=1)
    idx = jnp.arange(cfg.seq_len)[None, :]
    toks = jnp.where((idx >= lag) & copy_mask, rolled, toks).astype(jnp.int32)
    labels = jnp.roll(toks, -1, axis=1)
    batch = {"tokens": toks, "labels": labels,
             "loss_mask": jnp.ones_like(toks, jnp.float32).at[:, -1].set(0.0)}
    if frontend == "vision_patches" and frontend_tokens:
        batch["patch_embeds"] = 0.02 * jax.random.normal(
            k3, (cfg.global_batch, min(frontend_tokens, cfg.seq_len), d_model),
            jnp.bfloat16)
        batch["loss_mask"] = batch["loss_mask"].at[:, :frontend_tokens].set(0.0)
    if frontend == "audio_frames" and frontend_tokens:
        batch["frames"] = 0.02 * jax.random.normal(
            k3, (cfg.global_batch, frontend_tokens, d_model), jnp.bfloat16)
    return batch


def batch_for_model(model_cfg, shape, step: int) -> Dict[str, jnp.ndarray]:
    """Batch matching a (ModelConfig, InputShape) pair."""
    dcfg = DataConfig(model_cfg.vocab_size, shape.seq_len, shape.global_batch)
    return lm_batch(dcfg, step, d_model=model_cfg.d_model,
                    frontend=model_cfg.frontend,
                    frontend_tokens=model_cfg.frontend_tokens)


def data_iterator(model_cfg, shape, start_step: int = 0) -> Iterator[Dict[str, jnp.ndarray]]:
    step = start_step
    while True:
        yield batch_for_model(model_cfg, shape, step)
        step += 1
