"""mistral-nemo-12b — dense GQA decoder, 128k context.

[hf:mistralai/Mistral-Nemo-Base-2407] 40L, d_model=5120, 32 heads with
EXPLICIT head_dim=128 (q width 4096 != d_model — faithful to Nemo),
GQA kv=8, d_ff=14336, vocab=131072.

long_500k runs via the sliding-window variant (window 8192; see DESIGN.md §3).
"""
from repro.configs.base import ExitConfig, ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=131_072,
    attention="full",
    long_context_window=8192,
    rope="rope",
    rope_theta=1_000_000.0,
    exits=ExitConfig(exit_layers=(13, 26), entropy_threshold=0.5),
    source="hf:mistralai/Mistral-Nemo-Base-2407",
)
