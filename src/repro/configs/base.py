"""Configuration system for the repro framework.

Every assigned architecture is described by a single `ModelConfig` dataclass that
covers all six families (dense / moe / ssm / hybrid / encdec / vlm).  A config is
pure data: the model builder in `repro.models.model` dispatches on `family` and the
per-layer fields below.

Reduced "smoke" variants (2 layers, d_model <= 512, <= 4 experts) are derived
mechanically via `ModelConfig.reduced()` so smoke tests always exercise the same
code path as the full config.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# Input shapes (assigned, fixed for every architecture)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0           # routed experts
    top_k: int = 1
    num_shared_experts: int = 0
    d_ff_expert: int = 0           # per-expert FFN width
    capacity_factor: float = 1.25  # dispatch capacity factor
    layer_period: int = 1          # every `period`-th layer is MoE (1 = all)
    first_dense_layers: int = 0    # leading dense layers (DeepSeek-V3: 3)
    router_aux_coef: float = 0.01  # load-balance aux loss coefficient


@dataclass(frozen=True)
class SSMConfig:
    state_size: int = 64           # N, SSM state dimension
    conv_width: int = 4            # depthwise causal conv width (Mamba2)
    expand: int = 2                # inner expansion factor
    head_dim: int = 64             # Mamba2 SSD head dim (P)
    chunk_size: int = 256          # SSD chunked-scan block
    # xLSTM specifics
    slstm_layers: Tuple[int, ...] = ()  # layer indices using sLSTM (rest mLSTM)
    proj_factor: float = 2.0       # xLSTM block up-projection


@dataclass(frozen=True)
class EncDecConfig:
    num_encoder_layers: int = 0
    encoder_seq_len: int = 1500    # whisper: 30s audio -> 1500 frames


@dataclass(frozen=True)
class ExitConfig:
    """Early-exit (BranchyNet/Edgent) configuration.

    `exit_layers` are segment boundaries: after layer index i (1-based count of
    layers completed) an exit head may fire.  They also double as the candidate
    partition points for the collaborative-inference planners.
    """
    exit_layers: Tuple[int, ...] = ()
    entropy_threshold: float = 0.5
    head_hidden: int = 0           # 0 = linear head straight to vocab


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    # attention
    attention: str = "full"        # full | sliding | mla
    sliding_window: int = 0        # 0 = no sliding window (full attention)
    long_context_window: int = 8192  # window used by the long_500k sliding variant
    rope: str = "rope"             # rope | mrope | none (learned/sinusoidal stub)
    rope_theta: float = 10_000.0
    # MLA (DeepSeek-V3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # family sub-configs
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    encdec: EncDecConfig = field(default_factory=EncDecConfig)
    exits: ExitConfig = field(default_factory=ExitConfig)
    # hybrid (zamba2): shared attention block applied every `shared_attn_period`
    shared_attn_period: int = 0    # 0 = no shared block
    # vlm / audio frontend stub
    frontend: str = "none"         # none | audio_frames | vision_patches
    frontend_tokens: int = 0       # number of frontend embedding positions
    # misc
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    act: str = "silu"              # silu | gelu
    tie_embeddings: bool = False
    mtp_depth: int = 0             # DeepSeek-V3 multi-token-prediction depth
    dtype: str = "bfloat16"
    source: str = ""               # citation

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic long decode: SSM/hybrid state, or a sliding window."""
        if self.family in ("ssm", "hybrid"):
            return True
        if self.family == "encdec":
            return False  # whisper: pure full-attention enc-dec, skip long_500k
        return self.sliding_window > 0 or self.long_context_window > 0

    @property
    def is_decoder(self) -> bool:
        return True  # all assigned archs have a decode step

    def segment_boundaries(self) -> Tuple[int, ...]:
        """Segment boundaries = sorted exit layers + final layer.

        The segmented-scan model executes layers [b_{i-1}, b_i) as one
        `lax.scan`, evaluating an exit head / partition boundary between
        segments.  This is the uniform substrate for every collaborative
        technique in the survey.
        """
        bounds = sorted(set(self.exits.exit_layers) | {self.num_layers})
        return tuple(b for b in bounds if 0 < b <= self.num_layers)

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: 2 layers, d_model <= 512, <= 4 experts."""
        d_model = min(self.d_model, 256)
        num_heads = max(2, min(self.num_heads, 4))
        num_kv = max(1, min(self.num_kv_heads, num_heads))
        head_dim = max(8, d_model // num_heads)
        moe = self.moe
        if moe.num_experts:
            moe = dataclasses.replace(
                moe,
                num_experts=min(4, moe.num_experts),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=min(moe.d_ff_expert or 128, 128),
                first_dense_layers=min(moe.first_dense_layers, 1),
            )
        ssm = dataclasses.replace(
            self.ssm,
            state_size=min(self.ssm.state_size, 16),
            head_dim=min(self.ssm.head_dim, 32),
            chunk_size=32,
            slstm_layers=tuple(i for i in self.ssm.slstm_layers if i < 2) or ((1,) if self.ssm.slstm_layers else ()),
        )
        encdec = dataclasses.replace(
            self.encdec,
            num_encoder_layers=min(self.encdec.num_encoder_layers, 2),
            encoder_seq_len=min(self.encdec.encoder_seq_len, 32),
        )
        exits = dataclasses.replace(self.exits, exit_layers=(1,) if self.exits.exit_layers else ())
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=2,
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=num_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 1024),
            q_lora_rank=min(self.q_lora_rank, 64),
            kv_lora_rank=min(self.kv_lora_rank, 32),
            qk_nope_head_dim=min(self.qk_nope_head_dim, 32),
            qk_rope_head_dim=min(self.qk_rope_head_dim, 16),
            v_head_dim=min(self.v_head_dim, 32),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            long_context_window=min(self.long_context_window, 64),
            moe=moe,
            ssm=ssm,
            encdec=encdec,
            exits=exits,
            shared_attn_period=min(self.shared_attn_period, 1) if self.shared_attn_period else 0,
            frontend_tokens=min(self.frontend_tokens, 16) if self.frontend_tokens else 0,
            mtp_depth=min(self.mtp_depth, 1),
        )

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (used for Table-1 benchmark + roofline N)."""
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        emb = v * d * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            if self.attention == "mla":
                qr, kvr = self.q_lora_rank, self.kv_lora_rank
                qk = self.qk_nope_head_dim + self.qk_rope_head_dim
                p = d * qr + qr * nq * qk              # q down + up
                p += d * (kvr + self.qk_rope_head_dim)  # kv down (+ shared rope k)
                p += kvr * nq * (self.qk_nope_head_dim + self.v_head_dim)
                p += nq * self.v_head_dim * d          # o proj
                return p
            return d * nq * hd + 2 * d * nkv * hd + nq * hd * d

        def ffn_params(ff: int) -> int:
            mult = 3 if self.act == "silu" else 2  # gated vs plain
            return mult * d * ff

        def moe_layer_params() -> int:
            m = self.moe
            p = d * m.num_experts  # router
            p += m.num_experts * ffn_params(m.d_ff_expert)
            p += m.num_shared_experts * ffn_params(m.d_ff_expert)
            return p

        def ssm_layer_params() -> int:
            s = self.ssm
            d_in = s.expand * d
            nheads = max(1, d_in // s.head_dim)
            p = d * (2 * d_in + 2 * s.state_size + nheads)  # in_proj(x,z)+B,C,dt
            p += s.conv_width * (d_in + 2 * s.state_size)
            p += d_in * d + nheads  # out proj + A
            return p

        def xlstm_layer_params(layer_idx: int) -> int:
            s = self.ssm
            d_in = int(s.proj_factor * d)
            p = 2 * d * d_in + d_in * d  # up (x,z) + down
            p += 3 * d_in * d_in + 3 * d_in  # q,k,v / gates
            return p

        total = emb
        layers = self.num_layers
        for i in range(layers):
            if self.family in ("dense", "vlm"):
                total += attn_params() + ffn_params(self.d_ff)
            elif self.family == "moe":
                total += attn_params()
                m = self.moe
                if i < m.first_dense_layers or (m.layer_period > 1 and (i % m.layer_period) != (m.layer_period - 1)):
                    total += ffn_params(self.d_ff)
                else:
                    total += moe_layer_params()
            elif self.family == "ssm":
                if i in self.ssm.slstm_layers:
                    total += xlstm_layer_params(i)
                else:
                    total += xlstm_layer_params(i)
            elif self.family == "hybrid":
                total += ssm_layer_params()
            elif self.family == "encdec":
                total += attn_params() * 2 + ffn_params(self.d_ff)  # self+cross
            total += 2 * d  # norms
        if self.family == "hybrid" and self.shared_attn_period:
            total += attn_params() + ffn_params(self.d_ff)  # ONE shared block
        if self.family == "encdec":
            for _ in range(self.encdec.num_encoder_layers):
                total += attn_params() + ffn_params(self.d_ff) + 2 * d
        if self.mtp_depth:
            total += self.mtp_depth * (attn_params() + moe_layer_params() + 2 * d * d)
        # exit heads
        total += len(self.exits.exit_layers) * d * v if not self.tie_embeddings else 0
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.family != "moe":
            return self.param_count()
        m = self.moe
        full = self.param_count()
        # subtract inactive expert FFNs
        mult = 3 if self.act == "silu" else 2
        per_expert = mult * self.d_model * m.d_ff_expert
        n_moe_layers = sum(
            1 for i in range(self.num_layers)
            if i >= m.first_dense_layers and (m.layer_period <= 1 or (i % m.layer_period) == (m.layer_period - 1))
        )
        inactive = n_moe_layers * (m.num_experts - m.top_k) * per_expert
        return full - inactive

    def flops_per_token(self, seq_len: int) -> float:
        """Approximate forward FLOPs per token: 2*N_active + attention term."""
        n = self.active_param_count() - self.vocab_size * self.d_model  # exclude input embed gather
        f = 2.0 * n
        if self.family not in ("ssm",):
            win = self.sliding_window or seq_len
            ctx = min(seq_len, win)
            f += 4.0 * self.num_layers * self.num_heads * self.resolved_head_dim * ctx
        return f
