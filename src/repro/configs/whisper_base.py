"""whisper-base — encoder-decoder audio transformer backbone.

[arXiv:2212.04356] Robust Speech Recognition via Large-Scale Weak Supervision.
6L encoder + 6L decoder, d_model=512, 8 heads (MHA, kv=8), d_ff=2048,
vocab=51865.  The mel-spectrogram + conv frontend is a STUB: `input_specs()`
provides precomputed frame embeddings (B, 1500, 512).

long_500k is SKIPPED for this arch (pure full-attention enc-dec; see
DESIGN.md §3).
"""
from repro.configs.base import EncDecConfig, ExitConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    num_layers=6,                 # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51_865,
    attention="full",
    rope="none",                  # whisper uses learned/sinusoidal positions
    norm="layernorm",
    act="gelu",
    tie_embeddings=True,
    encdec=EncDecConfig(num_encoder_layers=6, encoder_seq_len=1500),
    exits=ExitConfig(exit_layers=(2, 4), entropy_threshold=0.5),
    frontend="audio_frames",
    frontend_tokens=1500,
    source="arXiv:2212.04356",
)
