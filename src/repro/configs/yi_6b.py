"""yi-6b — llama-architecture dense GQA decoder.

[arXiv:2403.04652] Yi: Open Foundation Models by 01.AI.  32L, d_model=4096,
32 heads, GQA kv=4, d_ff=11008, vocab=64000.

long_500k runs via the sliding-window variant (window 8192).
"""
from repro.configs.base import ExitConfig, ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11_008,
    vocab_size=64_000,
    attention="full",
    long_context_window=8192,
    rope="rope",
    rope_theta=5_000_000.0,
    exits=ExitConfig(exit_layers=(10, 21), entropy_threshold=0.5),
    source="arXiv:2403.04652",
)
