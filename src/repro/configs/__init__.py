"""Architecture registry: ``get_config("<arch-id>")`` and ``ARCHS``."""
from __future__ import annotations

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig

from repro.configs.whisper_base import CONFIG as _whisper
from repro.configs.zamba2_1p2b import CONFIG as _zamba2
from repro.configs.xlstm_350m import CONFIG as _xlstm
from repro.configs.mistral_nemo_12b import CONFIG as _nemo
from repro.configs.yi_6b import CONFIG as _yi
from repro.configs.llama4_maverick_400b import CONFIG as _llama4
from repro.configs.starcoder2_3b import CONFIG as _starcoder2
from repro.configs.qwen2_vl_2b import CONFIG as _qwen2vl
from repro.configs.deepseek_v3_671b import CONFIG as _dsv3
from repro.configs.granite_3_2b import CONFIG as _granite

ARCHS = {
    c.name: c
    for c in (
        _whisper, _zamba2, _xlstm, _nemo, _yi,
        _llama4, _starcoder2, _qwen2vl, _dsv3, _granite,
    )
}


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return ARCHS[name[: -len("-smoke")]].reduced()
    return ARCHS[name]


def shape_applicable(config: ModelConfig, shape_name: str) -> bool:
    """Whether an (arch, input-shape) pair is runnable (DESIGN.md §3 skips)."""
    shape = INPUT_SHAPES[shape_name]
    if shape.name == "long_500k" and not config.supports_long_context:
        return False
    return True


__all__ = [
    "ARCHS", "get_config", "shape_applicable",
    "INPUT_SHAPES", "InputShape", "ModelConfig",
]
