"""granite-3-2b — dense GQA decoder.

[hf:ibm-granite/granite-3.0-2b-base] 40L, d_model=2048, 32 heads
(head_dim=64), GQA kv=8, d_ff=8192, vocab=49155.

long_500k runs via the sliding-window variant.
"""
from repro.configs.base import ExitConfig, ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b",
    family="dense",
    num_layers=40,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=49_155,
    attention="full",
    long_context_window=8192,
    rope="rope",
    rope_theta=10_000.0,
    tie_embeddings=True,
    exits=ExitConfig(exit_layers=(13, 26), entropy_threshold=0.5),
    source="hf:ibm-granite/granite-3.0-2b-base",
)
