"""llama4-maverick-400b-a17b — MoE decoder, 128 routed experts top-1 + shared.

[hf:meta-llama/Llama-4-Scout-17B-16E family card] 48L, d_model=5120, 40 heads,
GQA kv=8, expert d_ff=8192, vocab=202048, MoE 128 experts top-1 with one
shared expert (Llama-4 style), MoE on every other layer interleaved with
dense FFN layers (d_ff 16384).

long_500k runs via chunked/sliding attention (Llama-4 uses chunked attention
for long context).
"""
from repro.configs.base import ExitConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16_384,                    # dense (non-MoE) interleaved layers
    vocab_size=202_048,
    attention="full",
    long_context_window=8192,
    rope="rope",
    rope_theta=500_000.0,
    moe=MoEConfig(
        num_experts=128,
        top_k=1,
        num_shared_experts=1,
        d_ff_expert=8192,
        capacity_factor=1.25,
        layer_period=2,             # every other layer MoE
        first_dense_layers=0,
    ),
    exits=ExitConfig(exit_layers=(16, 32), entropy_threshold=0.5),
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
