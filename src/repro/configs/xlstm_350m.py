"""xlstm-350m — xLSTM stack (mLSTM matrix-memory + sLSTM scalar-memory blocks).

[arXiv:2405.04517] xLSTM: Extended Long Short-Term Memory.  24 layers,
d_model=1024, 4 heads, d_ff=0 (xLSTM blocks use an internal up-projection
instead of a separate FFN), vocab=50304.  sLSTM blocks at layers 5/11/17/23
(xLSTM[7:1]-style ratio), the rest mLSTM.

long_500k RUNS: recurrent state is O(1) in sequence length.
"""
from repro.configs.base import ExitConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    attention="full",   # unused by ssm family
    rope="none",
    ssm=SSMConfig(
        state_size=64,
        head_dim=256,           # d_in=2048 / 4 heads -> matrix memory 256x256? capped in blocks
        chunk_size=256,
        slstm_layers=(5, 11, 17, 23),
        proj_factor=2.0,
    ),
    exits=ExitConfig(exit_layers=(8, 16), entropy_threshold=0.5),
    source="arXiv:2405.04517",
)
