"""starcoder2-3b — dense GQA decoder with NATIVE sliding-window attention.

[arXiv:2402.19173] StarCoder 2 and The Stack v2.  30L, d_model=3072,
24 heads, GQA kv=2, d_ff=12288, vocab=49152, RoPE, sliding window 4096
(faithful to StarCoder2) — so long_500k runs natively, no variant needed.
"""
from repro.configs.base import ExitConfig, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    d_ff=12_288,
    vocab_size=49_152,
    attention="sliding",
    sliding_window=4096,
    long_context_window=4096,
    rope="rope",
    rope_theta=999_999.4,
    norm="layernorm",
    act="gelu",
    exits=ExitConfig(exit_layers=(10, 20), entropy_threshold=0.5),
    source="arXiv:2402.19173",
)
