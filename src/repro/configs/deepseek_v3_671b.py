"""deepseek-v3-671b — MLA + fine-grained MoE (1 shared + 256 routed, top-8) + MTP.

[arXiv:2412.19437] DeepSeek-V3 Technical Report.  61L, d_model=7168,
128 heads, MLA (q_lora=1536, kv_lora=512, nope=128, rope=64, v=128),
expert d_ff=2048, 256 routed experts top-8 + 1 shared expert, first 3
layers dense (d_ff 18432), vocab=129280, multi-token prediction depth 1.

long_500k runs with the windowed-MLA variant (latent-cache ring buffer;
see DESIGN.md §3 — DeepSeek-V3 itself is full attention, the window is our
sub-quadratic long-context variant).
"""
from repro.configs.base import ExitConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=18_432,                   # first_dense_layers FFN width
    vocab_size=129_280,
    attention="mla",
    long_context_window=8192,
    rope="rope",
    rope_theta=10_000.0,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        num_shared_experts=1,
        d_ff_expert=2048,
        capacity_factor=1.25,
        layer_period=1,
        first_dense_layers=3,
    ),
    exits=ExitConfig(exit_layers=(20, 40), entropy_threshold=0.5),
    mtp_depth=1,
    source="arXiv:2412.19437",
)
