"""zamba2-1.2b — hybrid Mamba2 backbone with a shared attention block.

[arXiv:2411.15242] Zamba2 suite.  38 Mamba2 (SSD) layers, d_model=2048,
ssm_state=64, plus ONE weight-shared attention+MLP block (32H, d_ff=8192)
applied every `shared_attn_period` layers — the Zamba2 signature.

long_500k RUNS: Mamba2 state is O(1) per layer and the shared attention block
uses a sliding window in the long-context variant.
"""
from repro.configs.base import ExitConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32_000,
    attention="full",
    long_context_window=4096,
    rope="rope",
    ssm=SSMConfig(state_size=64, conv_width=4, expand=2, head_dim=64, chunk_size=256),
    shared_attn_period=6,
    exits=ExitConfig(exit_layers=(12, 24), entropy_threshold=0.5),
    source="arXiv:2411.15242",
)
