"""qwen2-vl-2b — VLM language backbone with M-RoPE.

[arXiv:2409.12191] Qwen2-VL.  28L, d_model=1536, 12 heads, GQA kv=2,
d_ff=8960, vocab=151936.  M-RoPE: rotary embedding split across
(temporal, height, width) position components.  The ViT vision encoder +
projector is a STUB: `input_specs()` provides patch embeddings merged into
the token stream (dynamic-resolution token count fixed per shape).

long_500k runs via the sliding-window variant.
"""
from repro.configs.base import ExitConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151_936,
    attention="full",
    long_context_window=8192,
    rope="mrope",
    rope_theta=1_000_000.0,
    exits=ExitConfig(exit_layers=(9, 18), entropy_threshold=0.5),
    frontend="vision_patches",
    frontend_tokens=1024,          # patch-embedding positions per request
    source="arXiv:2409.12191",
)
