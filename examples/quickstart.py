"""Quickstart: train a tiny model, serve it, read early-exit statistics.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.data import batch_for_model
from repro.models import Model
from repro.serving import ServeConfig, ServingEngine
from repro.training import (OptimizerConfig, TrainConfig, init_optimizer,
                            make_train_step)


def main():
    cfg = get_config("granite-3-2b-smoke")    # 2L reduced variant
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_optimizer(params)
    step = jax.jit(make_train_step(
        model, OptimizerConfig(lr=1e-3, warmup_steps=5, total_steps=60),
        TrainConfig(exit_loss_weight=0.3)))   # BranchyNet joint training

    shape = InputShape("quickstart", seq_len=64, global_batch=8, kind="train")
    print("training...")
    for i in range(60):
        batch = batch_for_model(cfg, shape, i)
        params, opt, metrics = step(params, opt, batch, jax.random.PRNGKey(i))
        if i % 15 == 0 or i == 59:
            print(f"  step {i:3d}  loss {float(metrics['loss']):.3f}  "
                  f"exit0_ce {float(metrics.get('exit0_ce', 0)):.3f}")

    print("serving...")
    engine = ServingEngine(model, params, ServeConfig(exit_threshold=0.8))
    prompts = jax.random.randint(jax.random.PRNGKey(7), (4, 8), 0,
                                 cfg.vocab_size)
    out = engine.generate(prompts, max_new=16)
    print(f"  generated {out.shape}; early-exit stats: "
          f"{ {k: round(v, 3) for k, v in engine.exit_stats().items()} }")


if __name__ == "__main__":
    main()
