"""The survey, end to end: plan all four collaborative-inference paradigms
for a workload, then execute the edge-device paradigm's ingredients for real
— early-exit serving + int8 boundary compression.

    PYTHONPATH=src python examples/collaborative_serving.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import Scenario, build_cost_graph, plan_all
from repro.core.cnn_zoo import CNN_ZOO
from repro.core.offload import (compress_boundary, compression_decision,
                                decompress_boundary)
from repro.kernels import ops as kops
from repro.models import Model
from repro.serving import (ClusterConfig, ContinuousBatchScheduler, Request,
                           SchedulerConfig, ServeConfig, ServingEngine,
                           TieredServingCluster)


def main():
    # ---- 1. plan the four paradigms (survey §3-§6) on a vision workload
    sc = Scenario.default()
    g = CNN_ZOO["vgg16"]()
    print("paradigm plans for vgg16 @ default scenario:")
    for name, p in plan_all(g, sc, deadline=0.1).items():
        print(f"  {name:18s} latency={p.latency*1e3:8.2f}ms "
              f"energy={p.energy:7.3f}J acc={p.accuracy:.3f} "
              f"comm={p.comm_bytes/1e6:8.2f}MB")

    # ...and on an assigned-zoo transformer (token inputs: cloud-only wins
    # on comm, exits still pay — the survey's scenario-dependence)
    g2 = build_cost_graph(get_config("qwen2-vl-2b"), batch=1, seq_len=1024)
    print("\nparadigm plans for qwen2-vl-2b (vision-language workload):")
    for name, p in plan_all(g2, sc, deadline=0.5).items():
        print(f"  {name:18s} latency={p.latency*1e3:8.2f}ms acc={p.accuracy:.3f}")

    # ---- 2. run the edge-device paradigm's runtime pieces: requests with
    # mixed prompt lengths flow through the continuous-batching scheduler
    # (slot pool + batched prefill + device-side exit counters)
    cfg = get_config("yi-6b-smoke")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = ContinuousBatchScheduler(
        model, params, SchedulerConfig(n_slots=2, max_len=32,
                                       exit_threshold=0.9, prefill_chunk=8))
    import numpy as np
    rs = np.random.RandomState(1)
    for length in (5, 8, 12, 7, 3, 10):
        sched.submit(Request(tokens=rs.randint(0, cfg.vocab_size, length),
                             max_new=12))
    sched.run()
    print(f"\ncontinuous batching (yi-6b-smoke): {sched.n_admitted} requests "
          f"through {sched.cfg.n_slots} slots, "
          f"jit caches {sched.jit_cache_sizes()}")
    print("early-exit serving stats:",
          {k: round(v, 3) for k, v in sched.exit_stats().items()})

    # How early exit changes serving latency: decode is depth-segmented —
    # the plan compiles into per-segment jitted stages bounded by exit
    # heads, and after each fused entropy probe the scheduler stops
    # dispatching segments once every active slot has exited.  A looser
    # threshold therefore *removes* layers from the step (measured as the
    # depth fraction below), which is what shrinks per-token latency — the
    # exit histogram above is bookkeeping, the depth fraction is FLOPs.
    # The tiered cluster charges its virtual clocks with that truncated
    # cost, so the threshold knob moves tier p50 directly (see
    # benchmarks/exit_bench.py for the full sweep).
    for thr in (0.0, 1.5):
        s2 = ContinuousBatchScheduler(
            model, params, SchedulerConfig(n_slots=2, max_len=32,
                                           exit_threshold=thr))
        for length in (6, 9):
            s2.submit(Request(tokens=rs.randint(0, cfg.vocab_size, length),
                              max_new=12))
        s2.run()
        print(f"  threshold {thr:3.1f}: measured depth fraction "
              f"{s2.measured_depth_fraction():.2f} "
              f"(stage dispatches {s2.stage_calls})")

    # ...the batch front-end (ServingEngine) rides on the same scheduler
    engine = ServingEngine(model, params, ServeConfig(exit_threshold=0.9))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0,
                                 cfg.vocab_size)
    engine.generate(prompts, max_new=12)
    print("engine batch stats:",
          {k: round(v, 3) for k, v in engine.exit_stats().items()})

    # ---- 3. the paradigms AS the runtime: the tiered cluster routes each
    # request to a cloud/edge/device scheduler pool at admission time
    # (planning against the full-size model, executing the smoke one)
    cluster = TieredServingCluster(
        model, params, sc, plan_cfg=get_config("yi-6b"),
        cfg=ClusterConfig(base_slots=2, max_len=280, prefill_chunk=16))
    t = 0.0
    for i in range(6):
        short = i % 3 != 2
        cluster.submit(
            rs.randint(0, cfg.vocab_size, 8 if short else 256),
            max_new=8, deadline=0.05 if short else None, arrival=t)
        t += 0.05
    cluster.run()
    cst = cluster.stats()
    print(f"\ntiered serving: routed {cst['route_counts']} "
          f"(p50 {cst['p50_latency_s']*1e3:.0f}ms virtual, "
          f"deadline hit {cst['deadline_hit_rate']:.2f})")
    for tname, ts in cst["tiers"].items():
        if ts["routed"]:
            print(f"  {tname:6s} slots={ts['n_slots']} "
                  f"routed={ts['routed']} util={ts['utilization']:.2f}")

    # ---- 4. a multi-tenant edge node: ONE pool multiplexing two
    # heterogeneous models (survey §6.3 dynamic task allocation).  Each
    # model owns its own cache arena + jitted stages behind one queue;
    # outputs are bit-identical to dedicated per-model schedulers.
    from repro.serving import ModelGroup, MultiModelScheduler
    cfg_b = get_config("xlstm-350m-smoke")
    model_b = Model(cfg_b)
    group = ModelGroup([
        ("yi", model, params),
        ("xlstm", model_b, model_b.init(jax.random.PRNGKey(3)))])
    pool = MultiModelScheduler(group, SchedulerConfig(n_slots=2, max_len=32))
    for i in range(6):
        name = ("yi", "xlstm")[i % 2]
        vocab = (cfg if name == "yi" else cfg_b).vocab_size
        pool.submit(Request(tokens=rs.randint(0, vocab, 4 + i), max_new=8,
                            model=name))
    pool.run()
    print(f"\nmulti-model pool: {len(pool.completed)} requests over "
          f"{list(pool.pools)} arenas, per-model tokens "
          f"{ {n: p.tokens_served for n, p in pool.pools.items()} }")

    # ---- 5. boundary feature compression (the partition-crossing tensor)
    x = jax.random.normal(jax.random.PRNGKey(2), (64, cfg.d_model), jnp.bfloat16)
    q, s = kops.compress_rows(x)                 # Pallas kernel (interpret)
    x2 = kops.decompress_rows(q, s)
    err = float(jnp.max(jnp.abs(x2.astype(jnp.float32) - x.astype(jnp.float32))))
    dec = compression_decision(
        float(x.size * 2), sc.device, sc.dev_edge)
    print(f"\nboundary compression: 2 bytes -> 1 byte/el, max abs err {err:.4f}, "
          f"planner says compress={dec.compress} (speedup {dec.speedup:.2f}x)")


if __name__ == "__main__":
    main()
