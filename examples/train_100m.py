"""End-to-end driver: train a ~100M-parameter decoder for a few hundred
steps on the synthetic copy-structured corpus, with BranchyNet exit heads
and checkpointing.

    PYTHONPATH=src python examples/train_100m.py --steps 300
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.configs.base import ExitConfig
from repro.launch.train import train


def make_100m_config():
    base = get_config("granite-3-2b")
    cfg = dataclasses.replace(
        base,
        name="granite-100m",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=4,
        head_dim=64,
        d_ff=3072,
        vocab_size=16_384,
        exits=ExitConfig(exit_layers=(4, 8), entropy_threshold=0.5),
    )
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()
    cfg = make_100m_config()
    params, metrics = train(
        cfg.name, steps=args.steps, batch=args.batch, seq=args.seq,
        lr=6e-4, ckpt_dir=args.ckpt, config_override=cfg, log_every=20)
    print("final metrics:", {k: round(v, 4) for k, v in metrics.items()})


if __name__ == "__main__":
    main()
