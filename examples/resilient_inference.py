"""Failure-resilient distributed inference (deepFogGuard/ResiliNet, survey
§5.2.3): train WITH failout, then show inference survives dead stages.

    PYTHONPATH=src python examples/resilient_inference.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core.resilience import n_scan_blocks, resilient_forward
from repro.data import batch_for_model
from repro.models import Model
from repro.models.common import softmax_cross_entropy
from repro.training import (OptimizerConfig, TrainConfig, init_optimizer,
                            make_train_step)


def eval_ce(model, params, batch, alive):
    logits, _ = resilient_forward(model, params, batch, alive)
    return float(softmax_cross_entropy(logits, batch["labels"],
                                       batch["loss_mask"]))


def main():
    cfg = get_config("granite-3-2b-smoke")
    shape = InputShape("r", 64, 8, "train")

    results = {}
    for failout_p, tag in ((0.0, "plain"), (0.25, "failout")):
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = init_optimizer(params)
        step = jax.jit(make_train_step(
            model, OptimizerConfig(lr=1e-3, warmup_steps=5, total_steps=80),
            TrainConfig(failout_prob=failout_p)))
        for i in range(80):
            b = batch_for_model(cfg, shape, i)
            params, opt, _ = step(params, opt, b, jax.random.PRNGKey(i))
        nb = n_scan_blocks(model)
        test = batch_for_model(cfg, shape, 999)
        all_alive = jnp.ones((nb,), jnp.float32)
        one_dead = all_alive.at[0].set(0.0)
        results[tag] = (eval_ce(model, params, test, all_alive),
                        eval_ce(model, params, test, one_dead))

    print("cross-entropy (lower=better):  all-alive | stage-0 dead")
    for tag, (full, dead) in results.items():
        print(f"  {tag:8s} {full:10.3f} | {dead:10.3f} "
              f"(degradation +{dead-full:.3f})")
    assert (results["failout"][1] - results["failout"][0]) < \
           (results["plain"][1] - results["plain"][0]) + 0.5, \
        "failout training should reduce failure degradation"
    print("-> failout training tolerates a dead stage better "
          "(ResiliNet, reproduced)")


if __name__ == "__main__":
    main()
