# Repo targets:
#   make test        tier-1 verify (ROADMAP.md): the whole suite, fail-fast
#   make test-fast   suite minus the slow dry-run compile test
#   make lint        byte-compile src/tests/benchmarks (import/syntax gate)
#   make analyze     static invariant analyzer (recompile hazards, Pallas
#                    tile legality) gated on analysis_baseline.json
#   make check       CI gate: lint + analyze + test-fast
#   make serve-bench continuous batching vs sequential serving throughput
#   make bench-smoke tiered (cloud/edge/device) serving benchmark, tiny trace
#   make bench-exit  early-exit threshold sweep (tok/s + p50 vs threshold)
#   make bench-multi multi-model pool vs swap-serving (mixed-model trace)
#   make bench-migrate  executed prefill/decode splits + tier-outage
#                    failover-by-migration vs requeue-and-recompute
#   make bench-paged paged KV arena capacity + radix prefix-cache hit rate
#   make bench-spec  cross-tier speculative decoding: lossless vs target-only
#                    greedy, measured acceptance, decode-rate + p50 wins on
#                    high-RTT links (assertion-gated, part of make check)
#   make bench-pipeline  overlapped decode pipeline vs sync poll(): smoke
#                    trace asserting overlap speedup + bit-parity (part of
#                    make check); bench-pipeline-full runs the 10^4-request
#                    acceptance trace with the 1.3x floor
#   make bench-targets  fail if benchmarks/run.py registers a bench with no
#                    Makefile target (consistency gate, part of make check)
.PHONY: test test-fast lint analyze check serve-bench bench-smoke \
	bench-exit bench-multi bench-migrate bench-paged bench-spec \
	bench-pipeline bench-pipeline-full bench-targets

test:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -x -q

# skip the slow dry-run compile test for quick iteration
test-fast:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -x -q -m "not slow"

lint:
	python -m compileall -q src tests benchmarks

analyze:
	PYTHONPATH=src python -m repro.analysis

check: lint analyze bench-targets test-fast bench-spec bench-pipeline

serve-bench:
	python benchmarks/serving_bench.py

bench-smoke:
	python benchmarks/tiered_serving_bench.py --smoke

bench-exit:
	python benchmarks/exit_bench.py

bench-multi:
	python benchmarks/multi_model_bench.py

bench-migrate:
	python benchmarks/migration_bench.py

bench-paged:
	python benchmarks/paged_kv_bench.py

bench-spec:
	python benchmarks/spec_decode_bench.py

bench-pipeline:
	python benchmarks/pipeline_bench.py --requests 600 --min-speedup 1.1

bench-pipeline-full:
	python benchmarks/pipeline_bench.py

bench-targets:
	python benchmarks/check_targets.py
