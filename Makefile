# Tier-1 verify (ROADMAP.md): the whole suite, fail-fast.
.PHONY: test test-fast serve-bench

test:
	PYTHONPATH=src python -m pytest -x -q

# skip the slow dry-run compile test for quick iteration
test-fast:
	PYTHONPATH=src python -m pytest -x -q -m "not slow"

serve-bench:
	python benchmarks/serving_bench.py
